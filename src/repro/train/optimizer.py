"""AdamW with fp32 master weights + moments, fully sharded (ZeRO-style: every
optimizer leaf inherits its parameter's sharding, which is itself FSDP x TP)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    use_master: bool = True


def lr_at(oc: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = oc.lr * (step + 1) / max(oc.warmup_steps, 1)
    t = jnp.clip((step - oc.warmup_steps) /
                 max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = oc.lr * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params, oc: OptimizerConfig):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
    }
    if oc.use_master:
        # jnp.array copies — params may already be f32 and astype would
        # alias (breaking buffer donation)
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32), params)
    return state


def abstract_opt_state(abstract_params, oc: OptimizerConfig):
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(f32, abstract_params),
        "v": jax.tree.map(f32, abstract_params),
    }
    if oc.use_master:
        state["master"] = jax.tree.map(f32, abstract_params)
    return state


def opt_state_logical(params_logical, oc: OptimizerConfig):
    state = {
        "step": (),
        "m": params_logical,
        "v": params_logical,
    }
    if oc.use_master:
        state["master"] = params_logical
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, oc: OptimizerConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(oc, step)
    b1, b2 = oc.beta1, oc.beta2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    src = opt_state.get("master", params)

    def upd(p_master, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / bc1, v / bc2
        pm = p_master.astype(jnp.float32)
        pm = pm - lr * (mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * pm)
        return pm, m, v

    flat_p, treedef = jax.tree.flatten(src)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])

    new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype),
                              new_master, params)
    new_state = {"step": step + 1, "m": new_m, "v": new_v}
    if "master" in opt_state:
        new_state["master"] = new_master
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
