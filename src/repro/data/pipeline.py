"""Deterministic, resumable, host-sharded synthetic token pipeline.

Batches derive from (seed, step, host_shard) through a counter-based hash —
any worker can reconstruct any step's batch (checkpoint resume and elastic
re-sharding need no data-state beyond the step counter). Double-buffered
prefetch thread hides host->device copy (the CAPI double-buffering analogue
of thesis §3.3.1).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


def _batch_rng(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


class TokenPipeline:
    """Synthetic LM batches with a Markov-ish structure so loss can fall."""

    def __init__(self, cfg: ModelConfig, seq_len: int, global_batch: int,
                 seed: int = 0, num_shards: int = 1, shard: int = 0):
        assert global_batch % num_shards == 0
        self.cfg = cfg
        self.seq = seq_len
        self.local_batch = global_batch // num_shards
        self.seed = seed
        self.shard = shard
        self.step = 0

    def batch_at(self, step: int) -> dict:
        rng = _batch_rng(self.seed, step, self.shard)
        v = self.cfg.vocab_size
        b, s = self.local_batch, self.seq
        # structured stream: tokens follow t+1 = (a*t + noise) mod v so a
        # model can learn next-token structure
        a = 31
        t0 = rng.integers(0, v, size=(b, 1))
        noise = rng.integers(0, 7, size=(b, s))
        toks = np.zeros((b, s), np.int64)
        toks[:, 0] = t0[:, 0]
        for i in range(1, s):
            toks[:, i] = (a * toks[:, i - 1] + noise[:, i]) % v
        batch = {}
        inputs = toks[:, :-1] if s > 1 else toks
        labels = toks[:, 1:] if s > 1 else toks
        pad = lambda x: np.pad(x, ((0, 0), (0, s - x.shape[1])))
        if self.cfg.external_embed:
            d = self.cfg.d_model
            emb = rng.standard_normal((b, s, d)).astype(np.float32)
            batch["embeds"] = emb
        else:
            batch["tokens"] = pad(inputs).astype(np.int32)
        batch["labels"] = pad(labels).astype(np.int32)
        if self.cfg.n_img_tokens:
            batch["image_embeds"] = rng.standard_normal(
                (b, self.cfg.n_img_tokens, self.cfg.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.batch_at(self.step)
            self.step += 1

    # -- resumable state ------------------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed, "shard": self.shard}

    def restore(self, state: dict):
        assert state["seed"] == self.seed and state["shard"] == self.shard, \
            "pipeline identity mismatch"
        self.step = state["step"]


class Prefetcher:
    """Background-thread double buffering (depth-2 queue)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)
            self.q.put(None)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
