"""Straggler detection: per-host step-time tracking with robust outlier
flagging (median + MAD). At fleet scale the supervisor uses this to evict
or deprioritize slow hosts; here it also powers tests and the trainer's
step-time health metric."""
from __future__ import annotations

import collections

import numpy as np


class StragglerMonitor:
    def __init__(self, n_hosts: int, window: int = 32, threshold: float = 3.5):
        self.n_hosts = n_hosts
        self.window = window
        self.threshold = threshold
        self.history = [collections.deque(maxlen=window)
                        for _ in range(n_hosts)]

    def record(self, host: int, step_time_s: float):
        self.history[host].append(step_time_s)

    def host_means(self) -> np.ndarray:
        return np.array([np.mean(h) if h else np.nan for h in self.history])

    def stragglers(self) -> list[int]:
        """Hosts whose mean step time is a MAD outlier above the median."""
        means = self.host_means()
        ok = ~np.isnan(means)
        if ok.sum() < 3:
            return []
        med = np.median(means[ok])
        mad = np.median(np.abs(means[ok] - med)) + 1e-9
        z = 0.6745 * (means - med) / mad
        return [i for i in range(self.n_hosts)
                if ok[i] and z[i] > self.threshold]

    def should_mitigate(self) -> bool:
        return len(self.stragglers()) > 0
