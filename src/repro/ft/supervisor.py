"""Restart supervisor: run a step loop with crash recovery from the latest
checkpoint (the single-controller view of a fleet-level supervisor). Used by
launch/train.py and the fault-tolerance tests (with injected failures)."""
from __future__ import annotations

import logging
import time
from typing import Callable, Optional

log = logging.getLogger("repro.supervisor")


class FailureInjector:
    """Deterministically raise at given steps (once each) — test hook
    standing in for preempted/killed hosts."""

    def __init__(self, fail_at_steps=()):
        self.pending = set(fail_at_steps)

    def maybe_fail(self, step: int):
        if step in self.pending:
            self.pending.discard(step)
            raise RuntimeError(f"injected node failure at step {step}")


class Supervisor:
    def __init__(self, max_restarts: int = 5, backoff_s: float = 0.0):
        self.max_restarts = max_restarts
        self.backoff_s = backoff_s
        self.restarts = 0

    def run(self, make_loop: Callable[[], Callable[[], None]]):
        """make_loop() -> run_fn; run_fn executes (resuming from the latest
        checkpoint internally) and returns when training completes."""
        while True:
            try:
                run_fn = make_loop()
                return run_fn()
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — supervisor catches all
                self.restarts += 1
                log.warning("worker failed (%s); restart %d/%d",
                            e, self.restarts, self.max_restarts)
                if self.restarts > self.max_restarts:
                    raise
                if self.backoff_s:
                    time.sleep(self.backoff_s)
