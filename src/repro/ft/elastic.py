"""Elastic scaling plan: map a checkpoint taken on one mesh onto another.

Checkpoints store logical (unsharded) arrays, so restore-on-new-mesh is a
device_put with the new shardings (checkpoint/checkpointer.py). This module
adds the *planning* layer: validate that a target mesh can host the model
(divisibility, memory estimate) and produce the new sharding tree.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh

from repro.models import Model
from repro.sharding.partition import tree_shardings
from repro.train.optimizer import OptimizerConfig, opt_state_logical
from repro.train.train_step import abstract_state


@dataclasses.dataclass
class ElasticPlan:
    ok: bool
    reasons: list
    shardings: object | None
    bytes_per_device: int


def plan_rescale(model: Model, oc: OptimizerConfig, mesh: Mesh,
                 hbm_bytes: int = 16 * 2 ** 30) -> ElasticPlan:
    reasons = []
    abstract = abstract_state(model, oc, None)
    logical = {"params": model.logical(),
               "opt": opt_state_logical(model.logical(), oc)}
    shardings = tree_shardings(abstract, logical, mesh)

    import jax
    total = 0
    n_dev = mesh.devices.size
    for leaf, sh in zip(jax.tree.leaves(abstract), jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        spec = sh.spec
        shard_factor = 1
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for ax in axes:
                shard_factor *= sizes[ax]
        total += nbytes // shard_factor
    if total > hbm_bytes:
        reasons.append(f"state {total / 2 ** 30:.1f} GiB/device exceeds HBM "
                       f"budget {hbm_bytes / 2 ** 30:.0f} GiB")
    return ElasticPlan(not reasons, reasons, shardings, total)
