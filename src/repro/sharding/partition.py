"""Logical-axis → mesh-axis partitioning rules (FSDP × TP × EP, pod-aware).

Every parameter/cache/batch leaf carries a tuple of logical axis names; the
rules engine maps them to mesh axes with divisibility checks and
no-mesh-axis-reuse per leaf. Non-divisible cases (36 heads on a 16-way model
axis, 40 experts, kv=8) degrade gracefully to the next candidate/replication —
the roofline table then shows the honest cost of that choice.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# candidates per logical axis, in priority order; entries are mesh-axis
# tuples (a tuple means "shard over the product of those axes").
DEFAULT_RULES: dict = {
    "batch": [("pod", "data"), ("data",)],
    "vocab": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "ffn": [("model",)],
    "experts": [("model",)],
    "ssm_inner": [("model",)],
    "ssm_proj": [("model",)],
    "ssm_heads": [("model",)],
    "lru": [("model",)],
    "kv_lora": [("model",)],
    "q_lora": [("model",)],
    "embed": [("pod", "data"), ("data",)],     # FSDP
    "kv_seq": [("model",)],                    # fallback cache sharding
    "seq": [],
    "head_dim": [],
    "layers": [],
    "lru_out": [],
    "capacity": [],
}

# Serving-time rules (the serve layer's `ServePlan`): inference holds no
# optimizer state worth FSDP-sharding, and the fused decode step cannot
# afford an embedding all-gather per token — embeddings, lm_head and norms
# replicate, only head/ffn dims are tensor-parallel over "model", and the
# decode batch rows ride the "data" axis. "vocab" replicates so every
# shard sees full logits (greedy argmax and categorical sampling need no
# collective); "experts" replicates because MoE top-k routing is local
# per token and must score every expert.
SERVE_RULES: dict = {**DEFAULT_RULES,
                     "embed": [],
                     "vocab": [],
                     "experts": [],
                     # SSD in/conv projections replicate: the fused step
                     # computes them at full width and slices the local
                     # head block (B/C channels are shared across heads)
                     "ssm_proj": [],
                     "batch": [("data",)]}

# axes resolved before others (so e.g. kv_heads grabs "model" before kv_seq)
PRIORITY = [
    "vocab", "heads", "kv_heads", "ffn", "experts", "ssm_inner", "ssm_heads",
    "lru", "kv_lora", "q_lora", "embed", "batch", "kv_seq",
]


def _mesh_sizes(mesh) -> dict:
    try:  # AbstractMesh (deviceless) and Mesh both expose axis_sizes
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    except (AttributeError, ValueError):
        return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             mesh: Mesh, rules: Optional[dict] = None) -> P:
    rules = rules or DEFAULT_RULES
    sizes = _mesh_sizes(mesh)
    assign: dict[int, tuple] = {}
    used: set = set()

    def prio(item):
        name = item[1]
        return PRIORITY.index(name) if name in PRIORITY else len(PRIORITY)

    order = sorted(((i, ln) for i, ln in enumerate(logical) if ln),
                   key=prio)
    for i, ln in order:
        for cand in rules.get(ln, []):
            cand = tuple(ax for ax in cand if ax in sizes)
            if not cand or any(ax in used for ax in cand):
                continue
            prod = math.prod(sizes[ax] for ax in cand)
            if shape[i] % prod == 0 and shape[i] >= prod:
                assign[i] = cand if len(cand) > 1 else cand
                used.update(cand)
                break
    entries = []
    for i in range(len(shape)):
        if i in assign:
            cand = assign[i]
            entries.append(cand if len(cand) > 1 else cand[0])
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_shardings(abstract_tree, logical_tree, mesh: Mesh,
                   rules: Optional[dict] = None):
    """NamedSharding tree matching an abstract (ShapeDtypeStruct) tree."""
    def f(a, lg):
        return NamedSharding(mesh, spec_for(a.shape, tuple(lg), mesh, rules))
    return jax.tree.map(f, abstract_tree, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def with_shardings(abstract_tree, logical_tree, mesh, rules=None):
    """ShapeDtypeStructs with shardings attached (for jit .lower inputs)."""
    sh = tree_shardings(abstract_tree, logical_tree, mesh, rules)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_tree, sh)


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(ax for ax in ("pod", "data") if ax in mesh.axis_names)


# ---------------------------------------------------------------------------
# Ambient activation-sharding context (MaxText-style logical constraints).
# Models call constrain(x, logical) everywhere; it is a no-op unless a mesh
# has been installed (so CPU tests and single-device runs are unaffected).
# ---------------------------------------------------------------------------
import contextlib
import contextvars

_ACT_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "activation_mesh", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: Optional[dict] = None):
    tok = _ACT_MESH.set((mesh, rules))
    try:
        yield
    finally:
        _ACT_MESH.reset(tok)


def constrain(x, logical: tuple):
    """Apply a with_sharding_constraint derived from logical axes (ambient)."""
    ctx = _ACT_MESH.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(x.shape, tuple(logical), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_logical(cfg, kind: str) -> dict:
    """Logical axes for the input batch of a given step kind."""
    if kind == "train":
        out = {"labels": ("batch", "seq")}
        if cfg.external_embed:
            out["embeds"] = ("batch", "seq", None)
        else:
            out["tokens"] = ("batch", "seq")
        if cfg.n_img_tokens:
            out["image_embeds"] = ("batch", None, None)
        return out
    if kind == "prefill":
        out = {}
        if cfg.external_embed:
            out["embeds"] = ("batch", "seq", None)
        else:
            out["tokens"] = ("batch", "seq")
        if cfg.n_img_tokens:
            out["image_embeds"] = ("batch", None, None)
        return out
    if kind == "decode":
        out = {}
        if cfg.external_embed:
            out["embeds"] = ("batch", None, None)
        else:
            out["tokens"] = ("batch", None)
        return out
    raise ValueError(kind)
